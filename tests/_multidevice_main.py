"""Multi-device correctness checks, run in a subprocess with 8 forced host
devices (the main pytest process must keep the default single device).

Usage: python tests/_multidevice_main.py
Exits 0 iff every distributed runner matches the single-device oracle.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import stencils  # noqa: E402
from repro.core import distribute  # noqa: E402
from repro.core.model import ParallelismConfig  # noqa: E402
from repro.kernels import ref  # noqa: E402


def check(name, spec, cfg, arrays, iters, rtol=2e-4):
    want = np.asarray(ref.stencil_iterations_ref(spec, arrays, iters))
    run = distribute.build_runner(spec, cfg, iterations=iters, tile_rows=16)
    got = run(arrays)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol, err_msg=name)
    print(f"OK {name}")


def main():
    assert jax.device_count() == 8, jax.device_count()
    rng = np.random.default_rng(7)
    cases = []
    for bench in ["jacobi2d", "hotspot", "dilate", "blur_jacobi2d"]:
        cases.append((bench, (96, 20), 4))
        cases.append((bench, (70, 13), 6))   # ragged rows
    for bench in ["heat3d", "jacobi3d"]:
        cases.append((bench, (64, 6, 6), 4))

    for bench, shape, iters in cases:
        spec = stencils.get(bench, shape=shape, iterations=iters)
        arrays = {
            n: jnp.asarray(rng.standard_normal(shp).astype(dt))
            for n, (dt, shp) in spec.inputs.items()
        }
        for cfg in [
            ParallelismConfig("spatial_s", k=4, s=1),
            ParallelismConfig("spatial_s", k=8, s=1),
            ParallelismConfig("spatial_r", k=2, s=1),
            ParallelismConfig("hybrid_s", k=4, s=2),
            ParallelismConfig("hybrid_s", k=2, s=3),
            ParallelismConfig("hybrid_r", k=2, s=2),
            ParallelismConfig("temporal", k=1, s=4),
            ParallelismConfig("temporal", k=1, s=3),  # iter not divisible
        ]:
            if cfg.variant in ("spatial_r", "hybrid_r"):
                R_k = -(-shape[0] // cfg.k)
                if iters * spec.radius > R_k:
                    continue
            check(f"{bench}{shape} it={iters} {cfg.variant}(k={cfg.k},s={cfg.s})",
                  spec, cfg, arrays, iters)

    # batched serving path: B independent grids through one shard_map
    # dispatch must equal B per-grid oracle runs (no cross-batch coupling)
    from repro.runtime.batching import build_batched_runner  # noqa: E402

    B = 3
    spec = stencils.get("jacobi2d", shape=(96, 20), iterations=4)
    xb = rng.standard_normal((B, 96, 20)).astype(np.float32)
    for cfg in [
        ParallelismConfig("spatial_s", k=4, s=1),
        ParallelismConfig("spatial_r", k=2, s=1),
        ParallelismConfig("hybrid_s", k=4, s=2),
        ParallelismConfig("hybrid_r", k=2, s=2),
        ParallelismConfig("temporal", k=1, s=4),
    ]:
        run = build_batched_runner(spec, cfg, iterations=4, tile_rows=16)
        got = run({"in_1": xb})
        assert got.shape == (B, 96, 20), got.shape
        for b in range(B):
            want = np.asarray(
                ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(xb[b])}, 4)
            )
            np.testing.assert_allclose(
                got[b], want, rtol=2e-4, atol=2e-4,
                err_msg=f"batched {cfg.variant} grid {b}",
            )
        print(f"OK batched {cfg.variant}(k={cfg.k},s={cfg.s}) "
              f"B={B} via {run.path}")

    # bucketed serving path: a design compiled for a padded bucket shape
    # (with the streamed exterior-zero mask woven into every stage) must
    # match the per-grid oracle on the REAL shard_map paths, including
    # grids whose rows don't divide the mesh
    from repro.runtime.batching import build_bucket_runner  # noqa: E402

    B = 2
    for bench, shape, bucket in [
        ("jacobi2d", (70, 13), (96, 20)),
        ("hotspot", (70, 13), (96, 20)),
    ]:
        spec = stencils.get(bench, shape=shape, iterations=4)
        arrays = {
            n: rng.standard_normal((B,) + shape).astype(dt)
            for n, (dt, _) in spec.inputs.items()
        }
        for cfg in [
            ParallelismConfig("spatial_s", k=4, s=1),
            ParallelismConfig("spatial_r", k=2, s=1),
            ParallelismConfig("hybrid_s", k=4, s=2),
            ParallelismConfig("hybrid_r", k=2, s=2),
            ParallelismConfig("temporal", k=1, s=4),
        ]:
            run = build_bucket_runner(
                spec, bucket, cfg, iterations=4, tile_rows=16
            )
            got = run(arrays)
            assert got.shape == (B,) + shape, got.shape
            for b in range(B):
                want = np.asarray(ref.stencil_iterations_ref(
                    spec,
                    {n: jnp.asarray(a[b]) for n, a in arrays.items()},
                    4,
                ))
                np.testing.assert_allclose(
                    got[b], want, rtol=2e-4, atol=2e-4,
                    err_msg=f"bucketed {bench} {cfg.variant} grid {b}",
                )
            print(f"OK bucketed {bench}{shape}->{bucket} "
                  f"{cfg.variant}(k={cfg.k},s={cfg.s}) via {run.path}")

    print("ALL MULTIDEVICE CHECKS PASSED")


if __name__ == "__main__":
    main()
