"""Multi-device correctness checks, run in a subprocess with 8 forced host
devices (the main pytest process must keep the default single device).

Usage: python tests/_multidevice_main.py
Exits 0 iff every distributed runner matches the single-device oracle.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import stencils  # noqa: E402
from repro.core import distribute  # noqa: E402
from repro.core.model import ParallelismConfig  # noqa: E402
from repro.core.spec import Boundary  # noqa: E402
from repro.kernels import ref  # noqa: E402


def check(name, spec, cfg, arrays, iters, rtol=2e-4):
    want = np.asarray(ref.stencil_iterations_ref(spec, arrays, iters))
    run = distribute.build_runner(spec, cfg, iterations=iters, tile_rows=16)
    got = run(arrays)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol, err_msg=name)
    print(f"OK {name}")


def main():
    assert jax.device_count() == 8, jax.device_count()
    rng = np.random.default_rng(7)
    cases = []
    for bench in ["jacobi2d", "hotspot", "dilate", "blur_jacobi2d"]:
        cases.append((bench, (96, 20), 4))
        cases.append((bench, (70, 13), 6))   # ragged rows
    for bench in ["heat3d", "jacobi3d"]:
        cases.append((bench, (64, 6, 6), 4))

    for bench, shape, iters in cases:
        spec = stencils.get(bench, shape=shape, iterations=iters)
        arrays = {
            n: jnp.asarray(rng.standard_normal(shp).astype(dt))
            for n, (dt, shp) in spec.inputs.items()
        }
        for cfg in [
            ParallelismConfig("spatial_s", k=4, s=1),
            ParallelismConfig("spatial_s", k=8, s=1),
            ParallelismConfig("spatial_r", k=2, s=1),
            ParallelismConfig("hybrid_s", k=4, s=2),
            ParallelismConfig("hybrid_s", k=2, s=3),
            ParallelismConfig("hybrid_r", k=2, s=2),
            ParallelismConfig("temporal", k=1, s=4),
            ParallelismConfig("temporal", k=1, s=3),  # iter not divisible
        ]:
            if cfg.variant in ("spatial_r", "hybrid_r"):
                R_k = -(-shape[0] // cfg.k)
                if iters * spec.radius > R_k:
                    continue
            check(f"{bench}{shape} it={iters} {cfg.variant}(k={cfg.k},s={cfg.s})",
                  spec, cfg, arrays, iters)

    # boundary-condition sweep on the REAL shard_map paths: every variant
    # x every boundary mode must match the oracle, including the periodic
    # wraparound ppermute exchange (device 0 <-> device k-1) and ragged
    # row shards for replicate/constant
    boundary_cfgs = [
        ParallelismConfig("spatial_s", k=8, s=1),   # per-iter ring exchange
        ParallelismConfig("spatial_s", k=4, s=1),
        ParallelismConfig("spatial_r", k=2, s=1),
        ParallelismConfig("hybrid_s", k=4, s=2),    # s*r ring per round
        ParallelismConfig("hybrid_r", k=2, s=2),
        ParallelismConfig("temporal", k=1, s=4),
    ]
    boundaries = [
        Boundary("constant", 1.5), Boundary("replicate"),
        Boundary("periodic"),
    ]
    for bench, shape, iters in [
        ("jacobi2d", (96, 20), 4),
        ("hotspot", (96, 20), 4),        # two inputs, one iterated
        ("blur_jacobi2d", (96, 20), 3),  # local stage chain
        ("heat3d", (64, 6, 6), 4),       # 3-D: two wrapped column dims
    ]:
        base = stencils.get(bench, shape=shape, iterations=iters)
        arrays = {
            n: jnp.asarray(rng.standard_normal(shp).astype(dt))
            for n, (dt, shp) in base.inputs.items()
        }
        for boundary in boundaries:
            spec = dataclasses.replace(base, boundary=boundary)
            for cfg in boundary_cfgs:
                if cfg.variant in ("spatial_r", "hybrid_r"):
                    R_k = -(-shape[0] // cfg.k)
                    if iters * spec.radius > R_k:
                        continue
                check(
                    f"boundary={boundary.kind} {bench}{shape} "
                    f"{cfg.variant}(k={cfg.k},s={cfg.s})",
                    spec, cfg, arrays, iters,
                )

    # ragged rows: periodic must REFUSE (wraparound adjacency broken),
    # replicate/constant must still be exact
    ragged = stencils.get("jacobi2d", shape=(70, 13), iterations=4)
    rag_arrays = {"in_1": jnp.asarray(
        rng.standard_normal((70, 13)).astype(np.float32))}
    for boundary in [Boundary("constant", 2.0), Boundary("replicate")]:
        check(
            f"ragged boundary={boundary.kind} jacobi2d(70,13) spatial_s(k=4)",
            dataclasses.replace(ragged, boundary=boundary),
            ParallelismConfig("spatial_s", k=4, s=1), rag_arrays, 4,
        )
    try:
        distribute.build_runner(
            dataclasses.replace(ragged, boundary=Boundary("periodic")),
            ParallelismConfig("spatial_s", k=4, s=1), iterations=4,
            tile_rows=16,
        )
    except ValueError as e:
        assert "wraparound" in str(e), e
        print("OK ragged periodic spatial_s refused:", str(e)[:60])
    else:
        raise AssertionError("ragged periodic sharding must refuse")

    # the new non-zero-boundary stock kernels end to end on 8 devices
    for bench, shape in [
        ("heat3d_periodic", (64, 6, 6)),
        ("blur_replicate", (96, 20)),
        ("sobel2d_replicate", (96, 20)),
    ]:
        spec = stencils.get(bench, shape=shape, iterations=4)
        arrays = {
            n: jnp.asarray(rng.standard_normal(shp).astype(dt))
            for n, (dt, shp) in spec.inputs.items()
        }
        for cfg in [
            ParallelismConfig("spatial_s", k=8, s=1),
            ParallelismConfig("hybrid_s", k=4, s=2),
        ]:
            check(f"stock {bench}{shape} {cfg.variant}(k={cfg.k},s={cfg.s})",
                  spec, cfg, arrays, 4)

    # batched serving path: B independent grids through one shard_map
    # dispatch must equal B per-grid oracle runs (no cross-batch coupling)
    from repro.runtime.batching import build_batched_runner  # noqa: E402

    B = 3
    spec = stencils.get("jacobi2d", shape=(96, 20), iterations=4)
    xb = rng.standard_normal((B, 96, 20)).astype(np.float32)
    for cfg in [
        ParallelismConfig("spatial_s", k=4, s=1),
        ParallelismConfig("spatial_r", k=2, s=1),
        ParallelismConfig("hybrid_s", k=4, s=2),
        ParallelismConfig("hybrid_r", k=2, s=2),
        ParallelismConfig("temporal", k=1, s=4),
    ]:
        run = build_batched_runner(spec, cfg, iterations=4, tile_rows=16)
        got = run({"in_1": xb})
        assert got.shape == (B, 96, 20), got.shape
        for b in range(B):
            want = np.asarray(
                ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(xb[b])}, 4)
            )
            np.testing.assert_allclose(
                got[b], want, rtol=2e-4, atol=2e-4,
                err_msg=f"batched {cfg.variant} grid {b}",
            )
        print(f"OK batched {cfg.variant}(k={cfg.k},s={cfg.s}) "
              f"B={B} via {run.path}")

    # bucketed serving path: a design compiled for a padded bucket shape
    # (with the streamed exterior-zero mask woven into every stage) must
    # match the per-grid oracle on the REAL shard_map paths, including
    # grids whose rows don't divide the mesh
    from repro.runtime.batching import build_bucket_runner  # noqa: E402

    B = 2
    for bench, shape, bucket in [
        ("jacobi2d", (70, 13), (96, 20)),
        ("hotspot", (70, 13), (96, 20)),
    ]:
        spec = stencils.get(bench, shape=shape, iterations=4)
        arrays = {
            n: rng.standard_normal((B,) + shape).astype(dt)
            for n, (dt, _) in spec.inputs.items()
        }
        for cfg in [
            ParallelismConfig("spatial_s", k=4, s=1),
            ParallelismConfig("spatial_r", k=2, s=1),
            ParallelismConfig("hybrid_s", k=4, s=2),
            ParallelismConfig("hybrid_r", k=2, s=2),
            ParallelismConfig("temporal", k=1, s=4),
        ]:
            run = build_bucket_runner(
                spec, bucket, cfg, iterations=4, tile_rows=16
            )
            got = run(arrays)
            assert got.shape == (B,) + shape, got.shape
            for b in range(B):
                want = np.asarray(ref.stencil_iterations_ref(
                    spec,
                    {n: jnp.asarray(a[b]) for n, a in arrays.items()},
                    4,
                ))
                np.testing.assert_allclose(
                    got[b], want, rtol=2e-4, atol=2e-4,
                    err_msg=f"bucketed {bench} {cfg.variant} grid {b}",
                )
            print(f"OK bucketed {bench}{shape}->{bucket} "
                  f"{cfg.variant}(k={cfg.k},s={cfg.s}) via {run.path}")

    # bucketed replicate/periodic on the real shard_map paths: the
    # streamed halo-index gather (replicate) and the host-streamed wrap
    # margin (periodic) must reproduce the oracle for ragged shapes across
    # bucket rungs — including shapes whose real/belt edge lands exactly
    # on a shard boundary — and widening the bucket must be bitwise
    # invariant (CPU backend: shape-stable elementwise codegen)
    from repro.runtime.bucketing import padded_request_shape  # noqa: E402

    halo_cfgs = [
        ParallelismConfig("spatial_s", k=4, s=1),
        ParallelismConfig("spatial_s", k=8, s=1),
        ParallelismConfig("spatial_r", k=2, s=1),
        ParallelismConfig("hybrid_s", k=4, s=2),
        ParallelismConfig("hybrid_r", k=2, s=2),
        ParallelismConfig("temporal", k=1, s=4),
    ]
    for kind in ["replicate", "periodic"]:
        for bench, shape, bucket in [
            ("jacobi2d", (70, 13), (96, 24)),
            ("jacobi2d", (48, 13), (96, 24)),    # edge on the k=4 boundary
            ("hotspot", (70, 13), (96, 24)),
            ("heat3d", (40, 6, 6), (64, 16, 16)),
        ]:
            spec = dataclasses.replace(
                stencils.get(bench, shape=shape, iterations=4),
                boundary=Boundary(kind),
            )
            need = padded_request_shape(spec, shape, 4)
            assert all(n <= b for n, b in zip(need, bucket)), (need, bucket)
            arrays = {
                n: rng.standard_normal((B,) + shape).astype(dt)
                for n, (dt, _) in spec.inputs.items()
            }
            for cfg in halo_cfgs:
                run = build_bucket_runner(
                    spec, bucket, cfg, iterations=4, tile_rows=16
                )
                got = run(arrays)
                assert got.shape == (B,) + shape, got.shape
                for b in range(B):
                    want = np.asarray(ref.stencil_iterations_ref(
                        spec,
                        {n: jnp.asarray(a[b]) for n, a in arrays.items()},
                        4,
                    ))
                    np.testing.assert_allclose(
                        got[b], want, rtol=2e-4, atol=2e-4,
                        err_msg=f"bucketed {kind} {bench}{shape} "
                                f"{cfg.variant}(k={cfg.k})",
                    )
                print(f"OK bucketed {kind} {bench}{shape}->{bucket} "
                      f"{cfg.variant}(k={cfg.k},s={cfg.s}) via {run.path}")

    # bitwise bucket-rung invariance on a multi-device config: the
    # minimal-fit streamed design and a wider rung must agree exactly
    for kind in ["replicate", "periodic"]:
        spec = dataclasses.replace(
            stencils.get("jacobi2d", shape=(70, 13), iterations=4),
            boundary=Boundary(kind),
        )
        arrays = {"in_1": rng.standard_normal((B, 70, 13)).astype(np.float32)}
        cfg = ParallelismConfig("spatial_s", k=4, s=1)
        minimal = padded_request_shape(spec, (70, 13), 4)
        # round rows up so every rung shares the k=4 row sharding geometry
        minimal = (-(-minimal[0] // 4) * 4,) + minimal[1:]
        base = build_bucket_runner(
            spec, minimal, cfg, iterations=4, tile_rows=16
        )(arrays)
        wide = build_bucket_runner(
            spec, (96, 24), cfg, iterations=4, tile_rows=16
        )(arrays)
        np.testing.assert_array_equal(base, wide, err_msg=f"rungs {kind}")
        print(f"OK bucketed {kind} bit-identical across rungs "
              f"{minimal} vs (96, 24)")

    # the replicate/periodic stock kernels end to end through the
    # bucketed path on 8 devices
    for bench, shape, bucket in [
        ("heat3d_periodic", (40, 6, 6), (64, 16, 16)),
        ("blur_replicate", (70, 13), (96, 24)),
        ("sobel2d_replicate", (70, 13), (96, 24)),
    ]:
        spec = stencils.get(bench, shape=shape, iterations=4)
        arrays = {
            n: rng.standard_normal((B,) + shape).astype(dt)
            for n, (dt, _) in spec.inputs.items()
        }
        for cfg in [
            ParallelismConfig("spatial_s", k=8, s=1),
            ParallelismConfig("hybrid_s", k=4, s=2),
        ]:
            run = build_bucket_runner(
                spec, bucket, cfg, iterations=4, tile_rows=16
            )
            got = run(arrays)
            for b in range(B):
                want = np.asarray(ref.stencil_iterations_ref(
                    spec,
                    {n: jnp.asarray(a[b]) for n, a in arrays.items()}, 4,
                ))
                np.testing.assert_allclose(
                    got[b], want, rtol=2e-4, atol=2e-4,
                    err_msg=f"stock bucketed {bench} {cfg.variant}",
                )
            print(f"OK stock bucketed {bench}{shape}->{bucket} "
                  f"{cfg.variant}(k={cfg.k},s={cfg.s})")

    # bucketed serving of a constant-boundary spec on the real shard_map
    # paths: mask+offset + margin fill must reproduce the oracle exactly
    spec = dataclasses.replace(
        stencils.get("jacobi2d", shape=(70, 13), iterations=4),
        boundary=Boundary("constant", 1.5),
    )
    arrays = {
        n: rng.standard_normal((B,) + (70, 13)).astype(dt)
        for n, (dt, _) in spec.inputs.items()
    }
    for cfg in [
        ParallelismConfig("spatial_s", k=4, s=1),
        ParallelismConfig("hybrid_s", k=4, s=2),
        ParallelismConfig("temporal", k=1, s=4),
    ]:
        run = build_bucket_runner(spec, (96, 20), cfg, iterations=4,
                                  tile_rows=16)
        got = run(arrays)
        for b in range(B):
            want = np.asarray(ref.stencil_iterations_ref(
                spec, {n: jnp.asarray(a[b]) for n, a in arrays.items()}, 4,
            ))
            np.testing.assert_allclose(
                got[b], want, rtol=2e-4, atol=2e-4,
                err_msg=f"bucketed constant-boundary {cfg.variant} grid {b}",
            )
        print(f"OK bucketed constant-boundary jacobi2d(70,13)->(96,20) "
              f"{cfg.variant}(k={cfg.k},s={cfg.s})")

    print("ALL MULTIDEVICE CHECKS PASSED")


if __name__ == "__main__":
    main()
