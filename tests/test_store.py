"""Persistent design store: cross-process warm start, quarantine,
invalidation, LRU interaction, telemetry restore, CLI.

The fast tests exercise the store through in-process ``DesignCache``
instances sharing one directory (what N replicas sharing a volume do);
the slow test proves the real thing across process boundaries with a
subprocess child (``store_child_main.py``, generated into tmp_path).
"""
import json
import os
import pickle
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import stencils
from repro.core import model
from repro.core.ir import lower
from repro.core.platform import DEFAULT_TPU
from repro.kernels import ref
from repro.runtime import DesignCache, DesignStore, environment_tag
from repro.runtime.cache import structural_fingerprint
from repro.runtime.store import design_key
from repro.store import main as store_cli

RNG = np.random.default_rng(23)


def small_spec(iterations=2, shape=(16, 8)):
    return stencils.jacobi2d(shape=shape, iterations=iterations)


def batch_for(spec, b=2):
    return {
        n: RNG.standard_normal((b,) + tuple(shape)).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    }


def oracle(spec, arrays, iters):
    one = {n: jnp.asarray(a[0]) for n, a in arrays.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


def serve_once(cache, spec, arrays):
    cached = cache.get_or_build(spec)
    return np.asarray(cached.runner(arrays)), cached


# --------------------------------------------------------------------------
# warm start within one machine (two caches sharing a directory)
# --------------------------------------------------------------------------


def test_warm_cache_skips_autotune_and_jit(tmp_path):
    spec = small_spec()
    arrays = batch_for(spec)

    cold = DesignCache(store=str(tmp_path / "store"))
    out_cold, _ = serve_once(cold, spec, arrays)
    assert cold.autotune_calls == 1
    assert cold.jit_builds == 1
    assert cold.store.stats.writes >= 2        # ranking + executable

    warm = DesignCache(store=str(tmp_path / "store"))
    out_warm, cached = serve_once(warm, spec, arrays)
    assert warm.autotune_calls == 0, "warm start re-ranked the design space"
    assert warm.jit_builds == 0, "warm start re-traced/re-compiled"
    assert warm.store_hits >= 1
    assert warm.store.stats.executable_hits >= 1
    np.testing.assert_array_equal(out_cold, out_warm)
    np.testing.assert_allclose(
        out_warm[0], oracle(spec, arrays, spec.iterations),
        rtol=2e-4, atol=2e-4,
    )


def test_autotune_store_entry_point(tmp_path):
    from repro.core import autotune

    spec = small_spec()
    x = {"in_1": RNG.standard_normal(spec.shape).astype(np.float32)}
    d1 = autotune(spec, store=str(tmp_path / "s"))
    want = d1.runner(x)

    cache = DesignCache(store=str(tmp_path / "s"))
    d2 = autotune(spec, cache=cache)
    assert cache.autotune_calls == 0           # ranking came from disk
    np.testing.assert_allclose(d2.runner(x), want, rtol=2e-4, atol=2e-4)

    other = DesignCache(store=str(tmp_path / "other"))
    with pytest.raises(ValueError, match="conflicts"):
        autotune(spec, cache=other, store=str(tmp_path / "s"))


# --------------------------------------------------------------------------
# corruption -> quarantine, never a crash
# --------------------------------------------------------------------------


def test_corrupt_entries_quarantined_not_fatal(tmp_path):
    spec = small_spec()
    arrays = batch_for(spec)
    root = tmp_path / "store"
    cold = DesignCache(store=str(root))
    out_cold, _ = serve_once(cold, spec, arrays)

    env = root / environment_tag()
    victims = sorted((env / "designs").glob("*.pkl")) + sorted(
        (env / "executables").glob("*.pkl")
    )
    assert victims, "cold pass wrote no entries"
    victims[0].write_bytes(b"garbage that is not a framed entry")
    victims[-1].write_bytes(victims[-1].read_bytes()[:20])   # truncated

    warm = DesignCache(store=str(root))
    out_warm, _ = serve_once(warm, spec, arrays)   # rebuilds what it must
    np.testing.assert_array_equal(out_cold, out_warm)
    assert warm.store.stats.quarantined >= 1
    q = env / "quarantine"
    assert q.is_dir() and any(q.iterdir()), "bad entries not moved aside"
    # the rebuild wrote fresh replacements: a third cache is fully warm
    third = DesignCache(store=str(root))
    serve_once(third, spec, arrays)
    assert third.autotune_calls == 0 and third.jit_builds == 0


# --------------------------------------------------------------------------
# version/environment invalidation
# --------------------------------------------------------------------------


def test_stale_environment_is_invisible_and_prunable(tmp_path):
    spec = small_spec()
    root = tmp_path / "store"
    stale_tag = "schema0-jax0.0.1-cpu"
    stale = DesignStore(root, env_tag=stale_tag)
    plat = DEFAULT_TPU.with_chips(1)
    key = design_key(structural_fingerprint(spec), spec.shape, plat, None)
    stale.put_design(key, spec, [])

    cur = DesignStore(root)
    assert cur.get_design(key) is None         # different env dir: a miss
    assert cur.stats.design_misses == 1
    assert set(cur.environments()) == {stale_tag, cur.env_tag}

    removed = cur.prune()
    assert stale_tag in removed
    assert cur.environments() == [cur.env_tag]
    manifest = json.loads((root / "manifest.json").read_text())
    assert manifest["environments"] == [cur.env_tag]


def test_schema_bump_invalidates(tmp_path, monkeypatch):
    import repro.runtime.store as store_mod

    spec = small_spec()
    root = tmp_path / "store"
    cache = DesignCache(store=str(root))
    cache.design(spec)
    assert cache.store.stats.writes >= 1

    monkeypatch.setattr(store_mod, "SCHEMA_VERSION", 2)
    bumped = DesignCache(store=str(root))
    assert bumped.store.env_tag.startswith("schema2-")
    bumped.design(spec)                         # miss: re-autotunes cleanly
    assert bumped.autotune_calls == 1
    assert bumped.store.stats.design_hits == 0


# --------------------------------------------------------------------------
# warm ranking whose top pick does not fit the current pool
# --------------------------------------------------------------------------


def test_warm_ranking_revalidates_against_current_pool(tmp_path):
    """A persisted ranking may lead with a config tuned for a bigger pool.
    The warm replica re-validates against ITS pool: by default it serves
    the top pick degraded — loudly (``DegradedDesignWarning``) — and
    under ``strict=True`` it refuses the degraded config and falls back
    to the persisted ranking's next truly-fitting candidate, recording a
    diagnostic.  Either way: no crash, no silent mismatch, no re-rank."""
    from repro.runtime import DegradedDesignWarning

    spec = small_spec()
    arrays = batch_for(spec)
    root = tmp_path / "store"
    lowered = lower(spec).spec

    from repro.runtime.batching import is_degraded

    big = model.choose_best(lowered, DEFAULT_TPU.with_chips(4))
    # genuinely degraded on one device (temporal cascades degenerate to
    # fused rounds silently by design, so pick a spatial/hybrid config)
    multi = next(p for p in big if is_degraded(p.config, 1))
    fit = [
        p for p in model.choose_best(lowered, DEFAULT_TPU.with_chips(1))
        if p.config.devices_needed <= 1
    ]
    assert fit, "no single-device candidate to fall back to"

    plat = DEFAULT_TPU.with_chips(1)            # what a 1-device pool ranks
    key = design_key(structural_fingerprint(spec), spec.shape, plat, None)
    DesignStore(root).put_design(key, lowered, [multi] + fit)

    warm = DesignCache(store=str(root))
    with pytest.warns(DegradedDesignWarning):
        out, cached = serve_once(warm, spec, arrays)
    assert warm.autotune_calls == 0             # ranking still came warm
    assert cached.design.config == multi.config  # degraded, not hidden
    np.testing.assert_allclose(
        out[0], oracle(spec, arrays, spec.iterations), rtol=2e-4, atol=2e-4,
    )

    strict = DesignCache(store=str(root))
    cached2 = strict.get_or_build(spec, strict=True)
    assert strict.autotune_calls == 0
    assert cached2.design.config.devices_needed <= 1
    assert cached2.design.diagnostics, "strict fallback left no diagnostic"
    out2 = np.asarray(cached2.runner(arrays))
    np.testing.assert_allclose(
        out2[0], oracle(spec, arrays, spec.iterations), rtol=2e-4, atol=2e-4,
    )


# --------------------------------------------------------------------------
# LRU eviction rebuilds from the store, not from scratch
# --------------------------------------------------------------------------


def test_lru_evicted_runner_rebuilds_from_store(tmp_path):
    a = small_spec(iterations=2, shape=(16, 8))
    b = stencils.blur(shape=(16, 8), iterations=2)
    xa, xb = batch_for(a), batch_for(b)

    cache = DesignCache(max_designs=1, store=str(tmp_path / "store"))
    out_a, _ = serve_once(cache, a, xa)
    serve_once(cache, b, xb)                    # evicts a's runner
    assert cache.runner_evictions >= 1
    builds_before = cache.jit_builds
    out_a2, _ = serve_once(cache, a, xa)        # rebuild wrapper, warm load
    assert cache.jit_builds == builds_before, (
        "evicted runner re-compiled instead of loading its executable"
    )
    assert cache.store.stats.executable_hits >= 1
    np.testing.assert_array_equal(out_a, out_a2)


# --------------------------------------------------------------------------
# telemetry persistence
# --------------------------------------------------------------------------


def test_telemetry_restored_across_restarts(tmp_path):
    spec = small_spec()
    root = str(tmp_path / "store")
    c1 = DesignCache(store=root)
    c1.design(spec)
    c1.design(spec)                             # 1 miss + 1 memory hit
    c1.flush_telemetry()

    c2 = DesignCache(store=root)
    restored = c2.stats()
    assert restored, "restart lost the per-key telemetry"
    (key, st), = [(k, s) for k, s in restored.items() if k[0] == "design"]
    assert st.misses == 1 and st.hits == 1
    assert st.build_time_s > 0
    # the restored counters keep accumulating, not restart from zero
    c2.design(spec)
    assert c2.stats()[key].store_hits == 1


def test_two_writer_telemetry_merge_is_lossless(tmp_path):
    """Regression: two caches sharing a store must not drop each other's
    counters.  Pre-fix, ``put_telemetry`` was last-writer-wins per key:
    writer B's ``{store_hits: 1, misses: 0}`` replaced writer A's
    ``{misses: 1}``, so a fresh reader saw the build history vanish."""
    spec = small_spec()
    root = str(tmp_path / "store")
    a = DesignCache(store=root)
    a.design(spec)                              # A: autotune miss, persisted
    b = DesignCache(store=root)
    b.design(spec)                              # B: warm store hit, persisted
    assert b.store_hits == 1

    c = DesignCache(store=root)                 # fresh reader merges both
    (key, st), = [(k, s) for k, s in c.stats().items() if k[0] == "design"]
    assert st.misses == 1, "writer B's flush dropped writer A's miss count"
    assert st.store_hits == 1, "writer A's history clobbered writer B's hit"
    assert st.build_time_s > 0


def test_store_level_counter_merge_policy(tmp_path):
    """get_telemetry merges writers field-wise: sums, max-of-maxes, OR'd
    booleans, and means recomputed from the merged sums (zero-guarded)."""
    root = tmp_path / "store"
    w1, w2 = DesignStore(root), DesignStore(root)
    w1.put_telemetry(
        {"k": {"hits": 2, "exec_total_s": 1.0, "exec_count": 2,
               "exec_max_s": 0.8, "exec_mean_s": 0.5}},
        {("s", (8, 8)): {"requests": 3, "cache_hit": False}},
    )
    w2.put_telemetry(
        {"k": {"hits": 5, "exec_total_s": 3.0, "exec_count": 6,
               "exec_max_s": 0.6, "exec_mean_s": 0.5}},
        {("s", (8, 8)): {"requests": 4, "cache_hit": True}},
    )
    tel = DesignStore(root).get_telemetry()
    k = tel["keys"]["k"]
    assert k["hits"] == 7 and k["exec_count"] == 8
    assert k["exec_total_s"] == pytest.approx(4.0)
    assert k["exec_max_s"] == pytest.approx(0.8)        # max, not sum
    assert k["exec_mean_s"] == pytest.approx(0.5)       # 4.0 / 8, recomputed
    bk = tel["buckets"][("s", (8, 8))]
    assert bk["requests"] == 7 and bk["cache_hit"] is True

    # zero-execution merge stays finite (the counter-edge guard)
    w1.put_telemetry(
        {"z": {"exec_total_s": 0.0, "exec_count": 0, "exec_mean_s": 0.0}}, {})
    w2.put_telemetry(
        {"z": {"exec_total_s": 0.0, "exec_count": 0, "exec_mean_s": 0.0}}, {})
    z = DesignStore(root).get_telemetry()["keys"]["z"]
    assert z["exec_mean_s"] == 0.0


def test_bucket_stats_restored_across_restarts(tmp_path):
    spec = small_spec(iterations=2, shape=(20, 12))
    root = str(tmp_path / "store")
    c1 = DesignCache(store=root)
    bd1 = c1.bucketed(spec)
    bd1.runner_for((20, 12), count=3)
    bucket, = bd1.buckets

    c2 = DesignCache(store=root)
    bd2 = c2.bucketed(spec)
    st = bd2.stats()
    assert bucket in st, "restart lost the per-bucket telemetry"
    assert st[bucket]["requests"] == 3
    bd2.runner_for((20, 12), count=2)           # resumes archived counters
    assert bd2.stats()[bucket]["requests"] == 5


# --------------------------------------------------------------------------
# readonly stores
# --------------------------------------------------------------------------


def test_readonly_store_never_writes(tmp_path):
    spec = small_spec()
    root = tmp_path / "store"
    DesignCache(store=str(root)).design(spec)   # populate

    ro = DesignStore(root, readonly=True)
    plat = DEFAULT_TPU.with_chips(1)
    key = design_key(structural_fingerprint(spec), spec.shape, plat, None)
    assert ro.get_design(key) is not None
    before = sorted(p.name for p in root.rglob("*"))
    ro.put_design("other-key", spec, [])
    ro.put_telemetry({"k": {"hits": 1}}, {})
    assert sorted(p.name for p in root.rglob("*")) == before
    assert ro.stats.writes == 0


# --------------------------------------------------------------------------
# the `python -m repro.store` CLI
# --------------------------------------------------------------------------


def test_store_cli_list_verify_prune(tmp_path, capsys):
    spec = small_spec()
    root = tmp_path / "store"
    cache = DesignCache(store=str(root))
    serve_once(cache, spec, batch_for(spec))

    assert store_cli(["list", str(root)]) == 0
    out = capsys.readouterr().out
    assert "design" in out and "executable" in out and "ok" in out

    assert store_cli(["verify", str(root)]) == 0

    victim = next((root / environment_tag() / "designs").glob("*.pkl"))
    victim.write_bytes(b"\x00corrupt")
    assert store_cli(["verify", str(root)]) == 1   # quarantines + reports
    out = capsys.readouterr().out
    assert "1 newly quarantined" in out
    assert store_cli(["verify", str(root)]) == 0   # now clean again
    out = capsys.readouterr().out
    assert "1 in quarantine backlog" in out
    # the corrupt entry sits in the quarantine backlog: plain verify is
    # green (nothing NEW quarantined) but --strict surfaces the backlog
    assert store_cli(["verify", str(root), "--strict"]) == 1
    out = capsys.readouterr().out
    assert "backlog" in out

    (root / "schema0-jax0.0.1-cpu" / "designs").mkdir(parents=True)
    assert store_cli(["prune", str(root)]) == 0
    out = capsys.readouterr().out
    assert "schema0-jax0.0.1-cpu" in out
    assert not (root / "schema0-jax0.0.1-cpu").exists()
    # prune emptied the current env's quarantine: strict is green again
    assert store_cli(["verify", str(root), "--strict"]) == 0


# --------------------------------------------------------------------------
# framed-entry integrity details
# --------------------------------------------------------------------------


def test_key_echo_rejects_wrong_entry(tmp_path):
    """A hand-copied/digest-colliding file serving the wrong design must
    read as a miss (key echo check), not as the wrong ranking."""
    spec = small_spec()
    root = tmp_path / "store"
    st = DesignStore(root)
    key = "a-key"
    st.put_design(key, spec, [])
    path = st._design_path(key)
    wrong = st._design_path("another-key")
    wrong.write_bytes(path.read_bytes())
    assert st.get_design("another-key") is None
    assert st.get_design(key) is not None


def test_executable_entry_rejects_foreign_pool(tmp_path):
    """Defense in depth: an executable whose recorded backend/device count
    disagrees with this process is a miss even if the key matches."""
    spec = small_spec()
    arrays = batch_for(spec)
    root = tmp_path / "store"
    cache = DesignCache(store=str(root))
    serve_once(cache, spec, arrays)

    env = root / environment_tag()
    path = next((env / "executables").glob("*.pkl"))
    raw = path.read_bytes()
    import hashlib

    from repro.runtime.store import _MAGIC

    body = pickle.loads(raw[len(_MAGIC) + 32:])
    body["meta"]["device_count"] = 4096         # some other machine's pool
    reframed = pickle.dumps(body, protocol=pickle.HIGHEST_PROTOCOL)
    path.write_bytes(
        _MAGIC + hashlib.sha256(reframed).digest() + reframed
    )

    warm = DesignCache(store=str(root))
    serve_once(warm, spec, arrays)
    assert warm.store.stats.executable_misses >= 1
    assert warm.jit_builds == 1                 # recompiled, did not load


# --------------------------------------------------------------------------
# the real thing: two fresh processes sharing one store directory
# --------------------------------------------------------------------------

CHILD_SRC = textwrap.dedent("""
    import json, sys
    import numpy as np
    from repro.configs import stencils
    from repro.runtime import DesignCache

    store_root, out_npy, report = sys.argv[1:4]
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    rng = np.random.default_rng(23)
    arrays = {
        n: rng.standard_normal((2,) + tuple(shape)).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    }
    cache = DesignCache(store=store_root)
    out = np.asarray(cache.get_or_build(spec).runner(arrays))
    cache.flush_telemetry()
    np.save(out_npy, out)
    json.dump({
        "autotune_calls": cache.autotune_calls,
        "jit_builds": cache.jit_builds,
        "store_hits": cache.store_hits,
    }, open(report, "w"))
""")


@pytest.mark.slow
def test_cross_process_round_trip(tmp_path):
    child = tmp_path / "store_child_main.py"
    child.write_text(CHILD_SRC)
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")

    def spawn(tag):
        out_npy = tmp_path / f"{tag}.npy"
        report = tmp_path / f"{tag}.json"
        subprocess.run(
            [sys.executable, str(child), str(tmp_path / "store"),
             str(out_npy), str(report)],
            check=True, env=env,
        )
        return np.load(out_npy), json.loads(report.read_text())

    out_cold, rep_cold = spawn("cold")
    out_warm, rep_warm = spawn("warm")
    assert rep_cold["autotune_calls"] == 1 and rep_cold["jit_builds"] == 1
    assert rep_warm["autotune_calls"] == 0, "warm process re-autotuned"
    assert rep_warm["jit_builds"] == 0, "warm process re-jitted"
    assert rep_warm["store_hits"] >= 1
    np.testing.assert_array_equal(out_cold, out_warm)
