"""IR lowering pipeline: pass semantics, op-delta reports, wiring."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import stencils
from repro.core import autotune, dsl, ir, model
from repro.core.ir import (
    eliminate_common_subexpressions,
    fold_constants,
    lower,
    simplify_algebraic,
)
from repro.core.spec import BinOp, Let, Num, Ref, Var, count_ops, walk
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


def _expr(text, shape=(8, 8)):
    spec = dsl.parse(f"""
kernel: T
iteration: 1
input float: x({shape[0]}, {shape[1]})
output float: o(0,0) = {text}
""")
    return spec, spec.output_stage.expr


# ---------------------------------------------------------------------------
# individual passes
# ---------------------------------------------------------------------------


def test_fold_constants():
    _, e = _expr("x(0,0) * (2 * 3) + max(1, 2, 5) - abs(0 - 4)")
    f = fold_constants(e)
    nums = [n.value for n in walk(f) if isinstance(n, Num)]
    assert 6.0 in nums and 5.0 in nums and 4.0 in nums
    assert count_ops(f) < count_ops(e)


def test_fold_preserves_division_by_zero():
    _, e = _expr("x(0,0) + 1 / 0")
    f = fold_constants(e)
    assert count_ops(f) == count_ops(e)  # 1/0 left for runtime inf


@pytest.mark.parametrize("text,expected_ops", [
    ("x(0,0) * 1", 0),           # x*1 -> x
    ("1 * x(0,0)", 0),           # 1*x -> x
    ("x(0,0) + 0", 0),           # x+0 -> x
    ("0 + x(0,0)", 0),           # 0+x -> x
    ("x(0,0) - 0", 0),           # x-0 -> x
    ("x(0,0) / 1", 0),           # x/1 -> x
    ("0 * x(0,1)", 0),           # 0*x -> 0
    ("x(0,1) * 0", 0),           # x*0 -> 0
    ("--x(0,0)", 0),             # double negation
    ("0 - (0 - x(0,0))", 0),     # exposes --x at the same node
    ("0 - x(0,0)", 1),           # 0-x -> -x (still one op)
])
def test_simplify_algebraic(text, expected_ops):
    _, e = _expr(text)
    assert count_ops(simplify_algebraic(fold_constants(e))) == expected_ops


def test_cse_binds_repeated_subtrees_once():
    _, e = _expr("(2 * x(0,0)) + (2 * x(0,0)) + (2 * x(0,0))")
    c = eliminate_common_subexpressions(e)
    assert isinstance(c, Let)
    assert count_ops(c) == 3      # one shared multiply + two adds
    assert count_ops(e) == 5


def test_cse_binds_repeated_refs():
    _, e = _expr("x(0,1) + x(0,1) + x(1,0)")
    c = eliminate_common_subexpressions(e)
    assert isinstance(c, Let)
    # the repeated tap is bound once; ops unchanged (refs are free)
    bound = [b for _, b in c.bindings]
    assert Ref("x", (0, 1)) in bound
    assert count_ops(c) == count_ops(e) == 2


def test_cse_nested_repeats_are_well_ordered():
    _, e = _expr("(x(0,1) + 1) + (x(0,1) + 1) + x(0,1)")
    c = eliminate_common_subexpressions(e)
    assert isinstance(c, Let)
    names = [n for n, _ in c.bindings]
    # the inner repeated tap binds before the tree containing it
    assert len(names) == 2
    inner_name, outer_name = names
    outer_expr = dict(c.bindings)[outer_name]
    assert Var(inner_name) in list(walk(outer_expr))


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


def test_lower_reduces_heat3d_with_report():
    spec = stencils.heat3d(shape=(16, 8, 8), iterations=2)
    low = lower(spec)
    assert low.ops_per_cell < spec.ops_per_cell
    assert low.ops_removed == spec.ops_per_cell - low.ops_per_cell
    assert [r.name for r in low.reports] == [
        "fold-constants", "simplify-algebraic", "cse"
    ]
    cse = low.reports[-1]
    assert cse.delta > 0
    assert "cse" in str(cse)
    assert spec.name in low.summary()


def test_lower_is_idempotent_for_all_stock_kernels():
    for name in stencils.BENCHMARKS:
        shape = (16, 8, 8) if name in stencils.BENCHMARKS_3D else (16, 8)
        spec = stencils.get(name, shape=shape, iterations=2)
        once = lower(spec).spec
        twice = lower(once).spec
        assert once == twice, name


def test_lowered_spec_evaluates_identically():
    """Lowering is semantics-preserving to the bit, per executor."""
    for name in ["heat3d", "hotspot", "sobel2d", "blur_jacobi2d"]:
        shape = (12, 5, 5) if name in stencils.BENCHMARKS_3D else (12, 9)
        spec = stencils.get(name, shape=shape, iterations=3)
        low = lower(spec).spec
        arrays = {
            n: jnp.asarray(RNG.standard_normal(shp).astype(dt))
            for n, (dt, shp) in spec.inputs.items()
        }
        want = np.asarray(ref.stencil_iterations_ref(spec, arrays, 3))
        np.testing.assert_array_equal(
            np.asarray(ref.stencil_iterations_ref(low, arrays, 3)), want,
            err_msg=f"ref {name}",
        )
        got = ops.stencil_run(low, arrays, 3, s=2, tile_rows=8,
                              backend="pallas")
        np.testing.assert_allclose(
            np.asarray(got), want, rtol=2e-4, atol=2e-4,
            err_msg=f"pallas {name}",
        )


def test_inline_lets_roundtrip():
    spec = stencils.heat3d(shape=(12, 5, 5), iterations=2)
    low = lower(spec).spec
    inlined = ir.inline_lets(low.output_stage.expr)
    assert not any(isinstance(n, (Let, Var)) for n in walk(inlined))
    # inlining restores the pre-CSE (folded/simplified) tree's op count
    assert count_ops(inlined) >= count_ops(low.output_stage.expr)


def test_lowered_spec_rejects_unbound_var():
    spec = stencils.jacobi2d(shape=(8, 8), iterations=1)
    bad = dataclasses.replace(
        spec,
        stages=(dataclasses.replace(
            spec.stages[0], expr=BinOp("+", Var("ghost"), Num(1.0))
        ),),
    )
    with pytest.raises(ValueError, match="unbound let-variable"):
        bad.validate()


# ---------------------------------------------------------------------------
# wiring: model + autotune consume post-optimization counts
# ---------------------------------------------------------------------------


def test_autotune_consumes_optimized_ops():
    spec = stencils.heat3d(shape=(64, 8, 8), iterations=2)
    design = autotune(spec, build=False)
    assert design.spec.ops_per_cell < spec.ops_per_cell
    assert any(r.delta > 0 for r in design.lowering)


def test_choose_best_optimize_flag_changes_compute_term():
    spec = stencils.heat3d(shape=(256, 16, 16), iterations=4)
    from repro.core.platform import DEFAULT_TPU

    tpu = DEFAULT_TPU.with_chips(1)
    raw = model.choose_best(spec, tpu, optimize=False)
    opt = model.choose_best(spec, tpu, optimize=True)
    raw_t = {p.config: p for p in raw}
    assert all(
        p.flops <= raw_t[p.config].flops for p in opt
    ) and any(p.flops < raw_t[p.config].flops for p in opt)


def test_cached_design_runs_lowered_spec():
    """The design cache compiles the optimized trees, not the raw DSL's."""
    from repro.runtime import DesignCache

    cache = DesignCache()
    spec = stencils.heat3d(shape=(16, 6, 6), iterations=2)
    cached = cache.get_or_build(spec, tile_rows=8)
    assert cached.design.spec.ops_per_cell < spec.ops_per_cell
    arrays = {
        n: RNG.standard_normal((2,) + shp).astype(dt)
        for n, (dt, shp) in spec.inputs.items()
    }
    out = cached.runner(arrays)
    for b in range(2):
        one = {n: jnp.asarray(a[b]) for n, a in arrays.items()}
        np.testing.assert_allclose(
            out[b], np.asarray(ref.stencil_iterations_ref(spec, one, 2)),
            rtol=2e-4, atol=2e-4,
        )
