"""Multi-device distribution correctness (subprocess: 8 forced host devices).

The main pytest process keeps the default single device (smoke tests and
benchmarks must see 1 device), so the shard_map equivalence checks run in a
child process with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_all_parallelisms_match_oracle_on_8_devices():
    script = os.path.join(os.path.dirname(__file__), "_multidevice_main.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True, text=True, timeout=900, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice checks failed\nstdout:\n{proc.stdout[-4000:]}\n"
            f"stderr:\n{proc.stderr[-4000:]}"
        )
    assert "ALL MULTIDEVICE CHECKS PASSED" in proc.stdout
