"""Replicated serving tier: worker protocol, routing, health, handoff.

Every test here spawns ``python -m repro.serve --worker`` subprocesses
(each imports jax), so the whole module is slow-marked: tier-1
(``scripts/ci.sh fast``) skips it, the full suite runs it.
"""
import subprocess
import sys
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import stencils
from repro.kernels import ref
from repro.serve import StencilRequest
from repro.serve.router import (
    ReplicaDied,
    StencilRouter,
    read_frame,
    write_frame,
)

pytestmark = pytest.mark.slow

RNG = np.random.default_rng(31)
ITERS = 2


def spec_16x8():
    return stencils.jacobi2d(shape=(16, 8), iterations=ITERS)


def grid_request(design, spec):
    return StencilRequest(design, {
        n: RNG.standard_normal(shape).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    })


def oracle(spec, req):
    one = {n: jnp.asarray(a) for n, a in req.arrays.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, ITERS))


def wait_until(predicate, timeout_s=30.0, what="condition"):
    deadline = time.monotonic() + timeout_s
    while not predicate():
        if time.monotonic() > deadline:
            raise TimeoutError(f"{what} not reached in {timeout_s}s")
        time.sleep(0.05)


def test_worker_protocol_roundtrip(tmp_path):
    """Speak the framed pickle protocol to one bare worker: ping,
    register, submit, exit — replies matched by id, grid correct."""
    import os

    import repro

    src_dir = str(
        __import__("pathlib").Path(next(iter(repro.__path__))).parent
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--worker",
         "--store", str(tmp_path / "store"), "--max-batch", "2"],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE, env=env,
    )
    try:
        spec = spec_16x8()
        write_frame(proc.stdin, {"id": 0, "op": "ping"})
        pong = read_frame(proc.stdout)
        assert pong["id"] == 0 and pong["ok"]
        assert pong["result"]["pid"] == proc.pid

        write_frame(proc.stdin, {
            "id": 1, "op": "register", "name": "jac", "spec": spec,
            "iterations": None,
        })
        reg = read_frame(proc.stdout)
        assert reg["id"] == 1 and reg["ok"]

        req = grid_request("jac", spec)
        write_frame(proc.stdin, {
            "id": 2, "op": "submit", "design": "jac",
            "arrays": req.arrays, "lane": None, "tenant": "default",
        })
        out = read_frame(proc.stdout)
        assert out["id"] == 2 and out["ok"]
        np.testing.assert_allclose(
            out["result"], oracle(spec, req), rtol=2e-4, atol=2e-4
        )

        write_frame(proc.stdin, {"id": 3, "op": "exit"})
        ack = read_frame(proc.stdout)
        assert ack["id"] == 3 and ack["ok"]
        assert proc.wait(timeout=30) == 0
    finally:
        proc.kill()
        proc.wait(timeout=30)


def test_router_fleet_serves_and_health_checks(tmp_path):
    spec = spec_16x8()
    with StencilRouter(tmp_path / "store", replicas=2,
                       max_batch=2) as router:
        router.register("jac", spec)
        reqs = [grid_request("jac", spec) for _ in range(5)]
        outs = router.serve(reqs)
        for req, out in zip(reqs, outs):
            np.testing.assert_allclose(
                out, oracle(spec, req), rtol=2e-4, atol=2e-4
            )
        health = router.ping()
        assert set(health) == {"replica-0", "replica-1"}
        assert all(info["healthy"] for info in health.values())
        served = sum(
            info["scheduler"]["completed"] for info in health.values()
        )
        assert served == 5
    # close() reaps every worker
    assert all(r.proc.poll() is not None for r in router._replicas)


def test_router_reroutes_after_replica_death(tmp_path):
    """Kill the replica that owns the design: routing skips the corpse
    and requests keep resolving on the survivor."""
    spec = spec_16x8()
    with StencilRouter(tmp_path / "store", replicas=2,
                       max_batch=2) as router:
        router.register("jac", spec)
        owner = router._route("jac")
        router.serve([grid_request("jac", spec)])

        owner.proc.kill()
        wait_until(lambda: not owner.healthy, what="death detection")

        reqs = [grid_request("jac", spec) for _ in range(3)]
        outs = router.serve(reqs)
        for req, out in zip(reqs, outs):
            np.testing.assert_allclose(
                out, oracle(spec, req), rtol=2e-4, atol=2e-4
            )
        survivor = router._route("jac")
        assert survivor is not owner and survivor.healthy
        health = router.ping()
        assert health[owner.name] == {"healthy": False}
        assert health[survivor.name]["healthy"]


def test_router_hands_off_inflight_requests_on_death(tmp_path):
    """Requests in flight on a replica when it dies are re-routed whole
    to a survivor (registration replayed first) — the client's futures
    resolve without resubmission."""
    spec = spec_16x8()
    with StencilRouter(tmp_path / "store", replicas=2,
                       max_batch=2) as router:
        router.register("jac", spec)
        owner = router._route("jac")
        reqs = [grid_request("jac", spec) for _ in range(4)]
        futures = [router.submit(r) for r in reqs]
        owner.proc.kill()
        for req, fut in zip(reqs, futures):
            np.testing.assert_allclose(
                fut.result(timeout=120.0), oracle(spec, req),
                rtol=2e-4, atol=2e-4,
            )


def test_router_fails_cleanly_with_no_survivors(tmp_path):
    spec = spec_16x8()
    router = StencilRouter(tmp_path / "store", replicas=1, max_batch=2)
    try:
        router.register("jac", spec)
        only = router._route("jac")
        future = router.submit(grid_request("jac", spec))
        only.proc.kill()
        wait_until(lambda: not only.healthy, what="death detection")
        # the one in-flight future either resolved before the kill or
        # fails with ReplicaDied — it must not hang
        try:
            future.result(timeout=60.0)
        except ReplicaDied:
            pass
        with pytest.raises(ReplicaDied):
            router.submit(grid_request("jac", spec))
    finally:
        router.close()
