"""Certified-numerics unit suite (:mod:`repro.core.numerics`).

Four layers:

  * **Exact-arithmetic soundness micro-cases** — pointwise kernels are
    evaluated both in true rational arithmetic (``fractions.Fraction``,
    exact for ``+ - * /``) and in per-op-rounded float32; the analyzer's
    envelope-mode bound must cover the measured |float32 - exact| at
    every cell.  This is soundness against *exact* reals, stronger than
    the conformance suite's executor-vs-oracle differential.
  * **Propagation properties** — division by a zero-straddling interval
    is never certified; CSE'd (lowered) trees never get a worse bound
    than their inlined form (shared subexpressions are analyzed once).
  * **Plumbing** — the SASA500 info diagnostic rides ``autotune``'s
    ``TunedDesign``; ``tolerance_for`` floors at one unit roundoff;
    ``ErrorReport.table()`` renders the per-stage budget.
  * **Lint CLI** — ``--format json`` / ``--format sarif`` schemas,
    ``--numerics`` attachment, ``--from-py`` literal scanning, and the
    exit-code contract (1 only on error severity, or warnings under
    ``--werror``).
"""
from __future__ import annotations

import io
import json
import math
from fractions import Fraction

import numpy as np
import pytest

from repro import lint
from repro.configs import stencils
from repro.core import dsl, numerics
from repro.core.autotune import autotune
from repro.core.ir import lower
from repro.core.platform import DEFAULT_TPU
from repro.core.spec import BinOp, Call, Neg, Num, Ref
from repro.core.spec import unit_roundoff

# ---------------------------------------------------------------------------
# Exact-arithmetic soundness micro-cases
# ---------------------------------------------------------------------------

# Pointwise (radius-0) kernels: every cell is independent, so the exact
# value is a scalar rational expression of the cell's inputs.
MICRO_POINTWISE = [
    """kernel: MICRO-ADDMUL
iteration: 1
input float: a(6, 6)
input float: b(6, 6)
output float: out(0, 0) = (a(0, 0) + b(0, 0)) * a(0, 0) - 0.125
""",
    """kernel: MICRO-DIV
iteration: 1
input float: a(6, 6)
input float: b(6, 6)
output float: out(0, 0) = a(0, 0) / (abs(b(0, 0)) + 2.0)
""",
    """kernel: MICRO-MINMAX
iteration: 1
input float: a(6, 6)
input float: b(6, 6)
output float: out(0, 0) = max(a(0, 0), min(b(0, 0), 0.5)) * b(0, 0)
""",
]


def _eval_exact(e, env):
    """Exact rational evaluation of a pointwise expression tree."""
    if isinstance(e, Num):
        return Fraction(float(e.value))
    if isinstance(e, Ref):
        assert all(o == 0 for o in e.offsets), "micro-cases are pointwise"
        return env[e.name]
    if isinstance(e, Neg):
        return -_eval_exact(e.arg, env)
    if isinstance(e, Call):
        args = [_eval_exact(a, env) for a in e.args]
        if e.fn == "abs":
            return abs(args[0])
        return max(args) if e.fn == "max" else min(args)
    if isinstance(e, BinOp):
        a, b = _eval_exact(e.lhs, env), _eval_exact(e.rhs, env)
        return {"+": a + b, "-": a - b, "*": a * b, "/": a / b}[e.op]
    raise TypeError(type(e))


def _eval_f32(e, env):
    """Per-op correctly-rounded float32 evaluation (a faithful executor)."""
    f32 = np.float32
    if isinstance(e, Num):
        return f32(float(e.value))
    if isinstance(e, Ref):
        return env[e.name]
    if isinstance(e, Neg):
        return f32(-_eval_f32(e.arg, env))
    if isinstance(e, Call):
        args = [_eval_f32(a, env) for a in e.args]
        if e.fn == "abs":
            return f32(abs(args[0]))
        return f32(max(args)) if e.fn == "max" else f32(min(args))
    if isinstance(e, BinOp):
        a, b = _eval_f32(e.lhs, env), _eval_f32(e.rhs, env)
        if e.op == "+":
            return f32(a + b)
        if e.op == "-":
            return f32(a - b)
        if e.op == "*":
            return f32(a * b)
        return f32(a / b)
    raise TypeError(type(e))


@pytest.mark.parametrize("text", MICRO_POINTWISE)
def test_envelope_bound_covers_exact_arithmetic(text):
    """|rounded-f32 eval - exact rational eval| <= certified bound,
    cell by cell — soundness against true reals, not another float."""
    spec = dsl.parse(text)
    rng = np.random.default_rng(42)
    arrays = {
        n: (rng.standard_normal(sh) * 3).astype(np.float32)
        for n, (_, sh) in spec.inputs.items()
    }
    rep = numerics.measured_report(spec, arrays, 1)
    assert rep.certified and rep.cell_err is not None
    expr = spec.output_stage.expr
    it = np.nditer(arrays[spec.iterate_input], flags=["multi_index"])
    for _ in it:
        idx = it.multi_index
        cell32 = {n: a[idx] for n, a in arrays.items()}
        exact = _eval_exact(expr, {
            n: Fraction(float(v)) for n, v in cell32.items()
        })
        got = _eval_f32(expr, cell32)
        err = abs(Fraction(float(got)) - exact)
        assert err <= Fraction(float(rep.cell_err[idx])), (
            f"{spec.name}@{idx}: |f32 - exact| = {float(err):.3g} exceeds "
            f"certified {float(rep.cell_err[idx]):.3g}"
        )


# ---------------------------------------------------------------------------
# Propagation properties
# ---------------------------------------------------------------------------

DIV_STRADDLE = """kernel: DIV-STRADDLE
iteration: 1
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) / b(0, 1)
"""

REPEATED_SUBEXPR = """kernel: CSE-CASE
iteration: 2
input float: a(8, 8)
output float: out(0, 0) = (a(0, 0) * a(0, 1) + 0.25) \
 * (a(0, 0) * a(0, 1) + 0.25)
"""


def test_zero_straddling_division_never_certified():
    spec = dsl.parse(DIV_STRADDLE)
    rep = numerics.analyze(spec, iterations=1)
    assert not rep.certified and not math.isfinite(rep.bound)
    # SASA301 (the interval-domain division check) owns this defect;
    # the numerics pass must not pile SASA501/503/510 on top of it.
    assert not any(
        d.code in ("SASA501", "SASA503", "SASA510") for d in rep.diagnostics
    )


def test_cse_bound_no_worse_than_inlined():
    """Lowering CSEs the repeated product; Let/Var reuse counts its
    error once, so the optimized tree's bound can only tighten."""
    spec = dsl.parse(REPEATED_SUBEXPR)
    inlined = numerics.analyze(spec, iterations=2, optimize=False)
    cse = numerics.analyze(
        lower(spec).spec, iterations=2, optimize=False,
    )
    assert cse.certified and inlined.certified
    assert cse.bound <= inlined.bound * (1 + 1e-12)


def test_static_vs_measured_consistency():
    """Measured envelopes on unit-range data stay within the static
    unit-range bound (the static interval mode covers every dataset
    drawn from the assumed range)."""
    spec = stencils.get("jacobi2d", shape=(12, 8), iterations=2)
    static = numerics.analyze(spec, iterations=2, input_range=1.0)
    rng = np.random.default_rng(7)
    arrays = {
        n: rng.uniform(-1, 1, sh).astype(np.float32)
        for n, (_, sh) in spec.inputs.items()
    }
    measured = numerics.measured_report(spec, arrays, 2)
    assert static.certified and measured.certified
    assert measured.bound <= static.bound


# ---------------------------------------------------------------------------
# Plumbing: reports, tolerances, TunedDesign attachment
# ---------------------------------------------------------------------------


def test_error_report_table_renders_budget():
    spec = stencils.get("jacobi2d", shape=(16, 8), iterations=2)
    rep = numerics.analyze(spec, iterations=2)
    table = rep.table()
    assert spec.output_name in table
    assert "certified" in table and "iteration(s)" in table
    assert f"{rep.bound:.3g}" in table


def test_tolerance_floor_is_unit_roundoff():
    spec = stencils.get("jacobi2d", shape=(8, 8), iterations=1)
    zeros = {
        n: np.zeros(sh, dtype=np.float32)
        for n, (_, sh) in spec.inputs.items()
    }
    tol = numerics.tolerance_for(spec, 1, zeros)
    assert tol == unit_roundoff(spec.dtype)


def test_autotune_attaches_certified_bound():
    spec = stencils.get("jacobi2d", shape=(32, 16), iterations=2)
    td = autotune(spec, platform=DEFAULT_TPU, iterations=2, build=False)
    found = [d for d in td.diagnostics if d.code == "SASA500"]
    assert len(found) == 1
    d = found[0]
    assert d.severity == "info" and d.stage == spec.output_name
    assert "certified rounding-error bound" in d.message
    assert d.span is not None


# ---------------------------------------------------------------------------
# Lint CLI: machine-readable output + exit-code contract
# ---------------------------------------------------------------------------

WARN_ONLY = """kernel: CANCEL-WARN
iteration: 1
input float: a(8, 8)
output float: out(0, 0) = (a(0, 0) + 100000000.0) - 100000000.0
"""

CLEAN = """kernel: CLEAN
iteration: 1
input float: a(8, 8)
output float: out(0, 0) = (a(0, -1) + a(0, 1)) / 2.0
"""


def test_lint_json_schema_and_exit_codes():
    buf = io.StringIO()
    code = lint.run([("warn.dsl", WARN_ONLY)], fmt="json", out=buf)
    assert code == 0  # warnings never gate without --werror
    doc = json.loads(buf.getvalue())
    assert doc["version"] == 1
    (entry,) = doc["files"]
    assert entry["file"] == "warn.dsl"
    codes = {d["code"] for d in entry["diagnostics"]}
    assert "SASA502" in codes
    d = next(x for x in entry["diagnostics"] if x["code"] == "SASA502")
    assert d["severity"] == "warning" and d["line"] == 4
    assert doc["summary"]["errors"] == 0
    assert doc["summary"]["warnings"] >= 1

    assert lint.run([("warn.dsl", WARN_ONLY)],
                    fmt="json", werror=True, out=io.StringIO()) == 1
    # error severity (zero-straddling streamed divisor) gates by itself
    assert lint.run([("bad.dsl", DIV_STRADDLE)],
                    fmt="json", out=io.StringIO()) == 1


def test_lint_sarif_output():
    buf = io.StringIO()
    lint.run([("warn.dsl", WARN_ONLY)], fmt="sarif", out=buf)
    doc = json.loads(buf.getvalue())
    assert doc["version"] == "2.1.0"
    (run_obj,) = doc["runs"]
    assert run_obj["tool"]["driver"]["name"] == "repro.lint"
    rules = {r["id"] for r in run_obj["tool"]["driver"]["rules"]}
    hits = {r["ruleId"] for r in run_obj["results"]}
    assert "SASA502" in rules and "SASA502" in hits
    loc = run_obj["results"][0]["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "warn.dsl"


def test_lint_numerics_json_attachment():
    buf = io.StringIO()
    code = lint.run(
        [("clean.dsl", CLEAN)], fmt="json", numerics_mode=True, out=buf,
    )
    assert code == 0
    (entry,) = json.loads(buf.getvalue())["files"]
    rep = entry["numerics"]
    assert rep["certified"] is True
    assert rep["bound"] is not None and rep["bound"] > 0
    assert [s["stage"] for s in rep["stages"]] == ["out"]


def test_lint_numerics_text_table():
    buf = io.StringIO()
    lint.run([("clean.dsl", CLEAN)], numerics_mode=True, out=buf)
    text = buf.getvalue()
    assert "certified numerics" in text
    assert "value envelope" in text


def test_lint_from_py_literal_scan(tmp_path):
    py = tmp_path / "embedded.py"
    py.write_text(
        "X = 1\n"
        f"KERNEL = '''{CLEAN}'''\n"
        "NOT_A_KERNEL = 'just a string'\n"
    )
    assert lint.dsl_literals(py.read_text()) == [CLEAN]
    buf = io.StringIO()
    import contextlib

    with contextlib.redirect_stdout(buf):
        code = lint.main(["--from-py", "--format", "json", str(py)])
    assert code == 0
    (entry,) = json.loads(buf.getvalue())["files"]
    assert entry["file"].endswith("embedded.py[0]")
